"""Lossless codec layer: framing, roundtrips, Table II-style ratios."""
import numpy as np
import pytest
from _hyp import given, settings, st   # optional-hypothesis shim

from repro.core import codecs


@pytest.mark.parametrize("codec", codecs.available())
@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.uint16, np.int64])
def test_roundtrip_all_codecs(codec, dtype, rng):
    arr = (rng.standard_normal((37, 21)) * 100).astype(dtype)
    blob, stats = codecs.encode(arr, codec)
    out = codecs.decode(blob)
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype
    assert stats.raw_bytes == arr.nbytes


def test_frame_self_describing(rng):
    arr = rng.standard_normal((3, 4, 5)).astype(np.float32)
    blob, _ = codecs.encode(arr, "bz2")
    out = codecs.decode(blob)   # no out-of-band metadata
    assert out.shape == (3, 4, 5)


def test_bad_magic_rejected():
    with pytest.raises(ValueError):
        codecs.decode(b"XXXX" + b"\x00" * 32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=3000),
    seed=st.integers(min_value=0, max_value=999),
    codec=st.sampled_from(["zlib", "bz2", "lzma", "none"]),
)
def test_roundtrip_property(n, seed, codec):
    r = np.random.default_rng(seed)
    arr = r.integers(-128, 127, size=n).astype(np.int8)
    out = codecs.decode(codecs.encode(arr, codec)[0])
    np.testing.assert_array_equal(out, arr)


def test_table2_ordering_on_float_data(rng):
    """Paper Table II: plain lossless on float scientific data removes only
    a few percent; zeros-heavy int8 (post-lossy) compresses drastically."""
    floats = rng.standard_normal(200_000).astype(np.float32)
    sparse = np.zeros(200_000, np.int8)
    sparse[rng.integers(0, 200_000, 4000)] = rng.integers(-127, 127, 4000)
    for codec in ("zlib", "bz2", "lzma"):
        cr_float = codecs.compression_ratio(floats, codec).ratio
        cr_sparse = codecs.compression_ratio(sparse, codec).ratio
        assert cr_float < 0.2, f"{codec} on random floats: {cr_float}"
        assert cr_sparse > 0.9, f"{codec} on sparse int8: {cr_sparse}"


def test_compression_stats_eq1():
    s = codecs.CompressionStats("zlib", 100, 25)
    assert s.ratio == pytest.approx(0.75)   # paper Eq. (1)


# -- chunked v2 framing -------------------------------------------------------

def _encode_v1(arr, codec="zlib"):
    """The pre-chunking frame layout, byte-for-byte (old checkpoints)."""
    import struct
    import zlib as _zlib
    comp = {"zlib": lambda b: _zlib.compress(b, 6), "none": lambda b: b}[codec]
    cid = {"zlib": 1, "none": 0}[codec]
    arr = np.ascontiguousarray(arr)
    raw = arr.tobytes()
    dt = np.dtype(arr.dtype).str.encode()
    return (codecs.MAGIC + struct.pack("<BBB", 1, cid, len(dt)) + dt
            + struct.pack("<B", arr.ndim)
            + struct.pack(f"<{arr.ndim}q", *arr.shape)
            + struct.pack("<q", len(raw)) + comp(raw))


@pytest.mark.parametrize("codec", ["zlib", "none"])
def test_v1_frame_backward_compat_decode(codec, rng):
    arr = rng.standard_normal((100, 7)).astype(np.float32)
    out = codecs.decode(_encode_v1(arr, codec))
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


@pytest.mark.parametrize("codec", ["zlib1", "bz2", "none"])
def test_multichunk_roundtrip(codec, rng):
    """A >1-chunk array: independent chunks reassemble exactly."""
    arr = rng.standard_normal(300_000).astype(np.float32)   # 1.2 MB
    blob, stats = codecs.encode(arr, codec, chunk_bytes=1 << 18)  # 5 chunks
    assert stats.raw_bytes == arr.nbytes
    out = codecs.decode(blob)
    np.testing.assert_array_equal(out, arr)


def test_chunk_pool_produces_identical_frames(rng):
    arr = rng.standard_normal(200_000).astype(np.float32)
    serial, _ = codecs.encode(arr, "zlib1", chunk_bytes=1 << 17)
    parallel, _ = codecs.encode(arr, "zlib1", chunk_bytes=1 << 17,
                                pool=codecs.codec_pool())
    assert serial == parallel               # pool changes time, not bytes
    out = codecs.decode(parallel, pool=codecs.codec_pool())
    np.testing.assert_array_equal(out, arr)


def test_chunked_frame_zero_size_and_0d(rng):
    empty = np.empty((0, 4), np.float32)
    out = codecs.decode(codecs.encode(empty, "zlib")[0])
    assert out.shape == (0, 4) and out.dtype == np.float32
    scalar = np.asarray(3.5, np.float32)
    out = codecs.decode(codecs.encode(scalar, "zlib")[0])
    # ascontiguousarray promotes 0-d to (1,) — same contract as v1 frames
    assert out.shape == (1,) and out[0] == np.float32(3.5)


def test_truncated_chunk_table_rejected(rng):
    """A chunk table that cannot cover raw_nbytes must raise, not decode a
    silently zero-filled tail (the v1 'frame length mismatch' guarantee)."""
    import struct
    arr = rng.standard_normal(200_000).astype(np.float32)   # 800 KB
    blob, _ = codecs.encode(arr, "zlib1", chunk_bytes=1 << 18)  # 4 chunks
    # header: MAGIC(4) ver/cid/dtlen(3) dt(3) ndim(1) shape(8) -> offset 19
    off = 4 + 3 + np.dtype(np.float32).str.encode().__len__() + 1 + 8
    raw_nbytes, chunk_bytes, n_chunks = struct.unpack_from("<qqI", blob, off)
    assert n_chunks == 4
    bad = bytearray(blob)
    struct.pack_into("<qqI", bad, off, raw_nbytes, chunk_bytes, 1)
    with pytest.raises(ValueError, match="chunk table"):
        codecs.decode(bytes(bad))


def test_unsupported_version_rejected(rng):
    arr = rng.standard_normal(16).astype(np.float32)
    blob, _ = codecs.encode(arr, "zlib")
    bad = blob[:4] + bytes([99]) + blob[5:]
    with pytest.raises(ValueError, match="version"):
        codecs.decode(bad)
