"""Logical-axis -> PartitionSpec rules (AbstractMesh: no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.distributed import sharding
from repro.models import params as P_lib, transformer
from repro.serving import kvcache

MESH = sharding.abstract_mesh((16, 16), ("data", "model"))
POD_MESH = sharding.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    spec = sharding.spec_for((2048, 8192), ("embed", "mlp"),
                             sharding.DEFAULT_RULES, MESH)
    assert spec == P("data", "model")


def test_indivisible_dims_replicate():
    # 9 heads don't divide 16 -> replicate that dim
    spec = sharding.spec_for((576, 9, 64), ("embed", "heads", "head_dim"),
                             sharding.DEFAULT_RULES, MESH)
    assert spec == P("data", None, None)


def test_mesh_axis_used_once_per_spec():
    rules = dict(sharding.DEFAULT_RULES, heads="model", mlp="model")
    spec = sharding.spec_for((32, 9728), ("heads", "mlp"), rules, MESH)
    flat = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(flat) == len(set(flat))


def test_tuple_rule_partial_divisibility():
    # vocab -> ('data','model'): 49152 divides 16 and 16*16
    spec = sharding.spec_for((49152,), ("vocab",), sharding.PURE_DP_RULES,
                             MESH)
    assert spec == P(("data", "model"))


def test_pod_never_shards_params():
    cfg = base.get("granite-3-2b")
    pspec = transformer.param_spec(cfg)
    specs = sharding.tree_partition_specs(
        P_lib.abstract(pspec), P_lib.logical_axes(pspec),
        sharding.DEFAULT_RULES, POD_MESH)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for part in s:
            parts = part if isinstance(part, tuple) else (part,)
            assert "pod" not in parts


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_every_arch_has_valid_specs(arch):
    cfg = base.get(arch)
    pspec = transformer.param_spec(cfg)
    specs = sharding.tree_partition_specs(
        P_lib.abstract(pspec), P_lib.logical_axes(pspec),
        sharding.DEFAULT_RULES, MESH)
    abstract = P_lib.abstract(pspec)
    sizes = dict(MESH.shape)
    for leaf, s in zip(jax.tree.leaves(abstract),
                       jax.tree.leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P))):
        for dim, part in zip(leaf.shape, tuple(s) + (None,) * 8):
            if part is None:
                continue
            parts = part if isinstance(part, tuple) else (part,)
            prod = 1
            for a in parts:
                prod *= sizes[a]
            assert dim % prod == 0, f"{arch}: {leaf.shape} vs {s}"


def test_batch_spec_divisibility():
    assert sharding.batch_spec(POD_MESH, 256) == P(("pod", "data"))
    assert sharding.batch_spec(POD_MESH, 2) == P("pod")
    assert sharding.batch_spec(POD_MESH, 1) == P()
    assert sharding.batch_spec(MESH, 32) == P("data")


def test_cache_seq_fallback_for_indivisible_kv_heads():
    cfg = base.get("qwen1.5-110b")  # kv=8 < model=16
    specs = kvcache.cache_partition_spec(cfg, 128, 32768, MESH)
    k_spec = specs["kv"]["k"]
    # (layers, batch, seq, kv_heads, head_dim): seq must take 'model'
    assert k_spec[2] == "model"


def test_cache_kv_heads_shard_when_divisible():
    cfg = base.get("moonshot-v1-16b-a3b")  # kv=16 == model
    specs = kvcache.cache_partition_spec(cfg, 128, 32768, MESH)
    assert specs["kv"]["k"][3] == "model"
