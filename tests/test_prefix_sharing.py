"""Shared-prefix COW page cache + snapshot-hydrated replicas.

Two load-bearing claims, both variants of bit-identity:

* **sharing is invisible** — requests that map a registered prefix chain
  read-only and prefill only their suffix decode token-for-token the same
  stream as requests that prefilled the whole prompt themselves. The only
  observable difference is the counter: far fewer prompt tokens prefilled.
* **hydration is exact** — a replica rebuilt from the snapshot chain
  (pool, tables, allocator free list + refcounts, registered prefixes,
  in-flight requests) decodes in lockstep with the producer from the
  first step, with zero prefill of its own.

Both rest on the refcount invariants of the ``PageAllocator``: a
referenced page is never reclaimed, over-free raises instead of
corrupting the free list, and eviction only ever takes chains no request
still maps. Those are property-tested (hypothesis when available, and a
seeded deterministic interleaving that always runs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs import base
from repro.models import params as P
from repro.models import transformer
from repro.serving import pages as PG
from repro.serving import prefix as PX
from repro.serving.engine import Request

SHAREABLE_ARCHS = ["smollm-135m", "deepseek-v3-671b", "moonshot-v1-16b-a3b"]
STATEFUL_ARCHS = ["hymba-1.5b", "xlstm-1.3b"]


def _mk(arch):
    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(0), transformer.param_spec(cfg))
    return cfg, prm


def _mk_engine(cfg, prm, **kw):
    kw.setdefault("num_pages", 17)
    kw.setdefault("page_size", 16)
    kw.setdefault("max_reqs", 4)
    kw.setdefault("prompt_len", 24)
    kw.setdefault("max_len", 64)
    return PG.PagedServingEngine(cfg, prm, **kw)


def _mk_requests(cfg, rng, n, prefix, max_new=4):
    return [Request(i, np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, size=4)]), max_new=max_new)
        for i in range(n)]


# ---------------------------------------------------------------------------
# sharing parity: COW-mapped prefixes decode the exact same tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", SHAREABLE_ARCHS)
def test_shared_prefix_token_parity(arch):
    cfg, prm = _mk(arch)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    a = _mk_requests(cfg, rng, 4, prefix)
    b = [Request(r.rid, r.prompt.copy(), max_new=r.max_new) for r in a]

    plain = _mk_engine(cfg, prm)
    plain.run(a, max_steps=64)
    shared = _mk_engine(cfg, prm)
    shared.register_prefix(prefix)
    shared.run(b, max_steps=64)

    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out == rb.out, f"request {ra.rid} diverged under sharing"

    ps, pp = plain.prefix_stats(), shared.prefix_stats()
    assert ps["prefill_tokens"] == 4 * 20         # every prompt in full
    assert pp["prefill_tokens"] == 16 + 4 * 4     # prefix once + suffixes
    assert pp["shared_tokens"] == 4 * 16
    assert pp["hits"] == 4 and pp["misses"] == 0 and pp["hit_rate"] == 1.0
    # everything retired, so only the cache's own reference remains
    assert shared.prefix_stats()["pages_saved"] == 0
    assert shared.allocator.refcounts() == {1: 1}  # the pinned prefix page


def test_shared_pages_counted_while_requests_are_live():
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    eng = _mk_engine(cfg, prm)
    eng.register_prefix(prefix)
    reqs = _mk_requests(cfg, rng, 3, prefix, max_new=8)
    for r in reqs:
        assert eng.admit(r)
    st_ = eng.prefix_stats()
    assert st_["shared_pages"] == 1               # the one prefix page
    assert st_["pages_saved"] == 3                # three COW references
    assert eng.allocator.refcount(eng.prefix.entries()[0].pages[0]) == 4
    eng.run(reqs, max_steps=64)
    assert all(r.done for r in reqs)
    assert eng.prefix_stats()["pages_saved"] == 0


def test_prompt_equal_to_prefix_is_not_shared():
    """The continuation prefill needs >= 1 divergent token, so a prompt
    exactly equal to a registered prefix prefills normally (miss)."""
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    eng = _mk_engine(cfg, prm)
    eng.register_prefix(prefix)
    req = Request(0, prefix.copy(), max_new=2)
    eng.run([req], max_steps=32)
    assert req.done
    st_ = eng.prefix_stats()
    assert st_["hits"] == 0 and st_["misses"] == 1


@pytest.mark.parametrize("arch", STATEFUL_ARCHS)
def test_register_prefix_rejects_stateful_families(arch):
    cfg, prm = _mk(arch)
    eng = _mk_engine(cfg, prm)
    with pytest.raises(ValueError, match="per-row recurrent state"):
        eng.register_prefix(np.arange(16))


def test_register_prefix_too_short_raises():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm)
    with pytest.raises(ValueError, match="shorter than one page"):
        eng.register_prefix(np.arange(7))


def test_register_prefix_idempotent():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm)
    toks = np.arange(16)
    k1 = eng.register_prefix(toks)
    free_after = eng.allocator.free_pages
    k2 = eng.register_prefix(toks)
    assert k1 == k2
    assert eng.allocator.free_pages == free_after
    assert len(eng.prefix) == 1


def test_prefix_match_longest_strictly_shorter():
    cache = PX.PrefixCache()
    short = np.arange(16, dtype=np.int32)
    long = np.arange(32, dtype=np.int32)
    cache.add(PX.PrefixEntry(key="s", tokens=short, pages=[1]))
    cache.add(PX.PrefixEntry(key="l", tokens=long, pages=[2, 3]))
    hit = cache.match(np.arange(40))
    assert hit is not None and hit.key == "l"     # longest wins
    hit = cache.match(np.arange(32))              # equal length -> shorter
    assert hit is not None and hit.key == "s"
    assert cache.match(np.arange(3, 40)) is None  # content mismatch


# ---------------------------------------------------------------------------
# allocator refcount invariants
# ---------------------------------------------------------------------------

def _check_allocator_invariants(alloc):
    live = alloc.refcounts()
    assert all(c >= 1 for c in live.values())
    assert not (set(alloc._free) & set(live))       # free xor referenced
    assert len(set(alloc._free)) == len(alloc._free)
    assert len(alloc._free) + len(live) == alloc.num_pages - 1
    assert 0 not in live and 0 not in alloc._free   # scratch never managed


def _drive_allocator(num_pages, ops):
    """Replay (op, arg) interleavings; invalid frees/shares must raise and
    leave state untouched. Returns the allocator for final checks."""
    alloc = PG.PageAllocator(num_pages)
    chains = []                                      # live chains we own
    for op, arg in ops:
        if op == "alloc":
            pages = alloc.alloc(1 + arg % 3)
            if pages is not None:
                chains.append(pages)
        elif op == "share" and chains:
            pages = chains[arg % len(chains)]
            alloc.share(pages)
            chains.append(list(pages))
        elif op == "free" and chains:
            alloc.free(chains.pop(arg % len(chains)))
        elif op == "bad_free":
            before = alloc.state_dict()
            freed = {p for c in chains for p in c}
            victim = next((p for p in range(1, num_pages)
                           if p not in freed and alloc.refcount(p) == 0),
                          None)
            if victim is not None:
                with pytest.raises(ValueError):
                    alloc.free([victim])            # double/foreign free
                assert alloc.state_dict() == before
        _check_allocator_invariants(alloc)
    return alloc, chains


def test_allocator_interleavings_deterministic():
    rng = np.random.default_rng(0)
    names = ["alloc", "share", "free", "bad_free"]
    for trial in range(25):
        ops = [(names[rng.integers(0, 4)], int(rng.integers(0, 100)))
               for _ in range(40)]
        alloc, chains = _drive_allocator(9, ops)
        for c in list(chains):                      # full drain reclaims all
            alloc.free(c)
        assert alloc.free_pages == 8
        assert alloc.refcounts() == {}


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.tuples(
    st.sampled_from(["alloc", "share", "free", "bad_free"]),
    st.integers(min_value=0, max_value=99)), max_size=60))
def test_allocator_interleavings_property(ops):
    alloc, chains = _drive_allocator(9, ops)
    for c in list(chains):
        alloc.free(c)
    assert alloc.free_pages == 8 and alloc.refcounts() == {}


def test_referenced_page_survives_owner_free():
    alloc = PG.PageAllocator(5)
    chain = alloc.alloc(2)
    alloc.share(chain)                              # a reader maps it
    alloc.free(chain)                               # the owner retires
    assert all(alloc.refcount(p) == 1 for p in chain)
    assert not (set(alloc._free) & set(chain))      # never reclaimed
    alloc.free(chain)                               # reader retires
    assert alloc.free_pages == 4


def test_eviction_only_takes_unreferenced_chains():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm, num_pages=6, prompt_len=40, max_len=48)
    rng = np.random.default_rng(3)
    pinned = rng.integers(0, cfg.vocab_size, size=16)
    idle = rng.integers(0, cfg.vocab_size, size=32)
    k_pin = eng.register_prefix(pinned)
    k_idle = eng.register_prefix(idle)
    req = Request(0, np.concatenate(
        [pinned, rng.integers(0, cfg.vocab_size, size=4)]), max_new=8)
    assert eng.admit(req)                           # holds a ref on pinned
    # pool pressure: the next admit must evict, and must pick the idle
    # chain (LRU among refcount-1 chains), never the one req still maps
    assert eng.prefix.evict_lru(eng.allocator)
    assert eng.prefix.get(k_idle) is None
    assert eng.prefix.get(k_pin) is not None
    assert not eng.prefix.evict_lru(eng.allocator)  # pinned chain is shared
    eng.run([req], max_steps=64)
    assert req.done


def test_admit_evicts_lru_prefix_under_pressure():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm, num_pages=4, prompt_len=24, max_len=48)
    rng = np.random.default_rng(4)
    eng.register_prefix(rng.integers(0, cfg.vocab_size, size=16))
    assert eng.allocator.free_pages == 2
    # a 3-page admit only fits if the idle prefix chain is evicted
    req = Request(0, rng.integers(0, cfg.vocab_size, size=20), max_new=16)
    assert eng.admit(req)
    assert len(eng.prefix) == 0
    assert eng.prefix.stats()["evictions"] == 1
    eng.run([req], max_steps=64)
    assert req.done


def test_matching_admit_under_pressure_never_evicts_its_own_prefix():
    """A shared admit must not LRU-evict the very prefix it just matched:
    the shared reference is taken before suffix allocation, so under
    pressure the matched chain is refcount-2 (never an eviction
    candidate) and a pool that can't fit the suffix rejects cleanly —
    allocator and prefix cache bit-identical to before the attempt."""
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm, num_pages=4, prompt_len=24, max_len=48)
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    key = eng.register_prefix(prefix)
    blocker = Request(0, rng.integers(0, cfg.vocab_size, size=16),
                      max_new=16)
    assert eng.admit(blocker)                   # 2 pages -> pool exhausted
    assert eng.allocator.free_pages == 0
    req = Request(1, np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, size=4)]), max_new=8)
    before_alloc = eng.allocator.state_dict()
    before_pages = list(eng.prefix.get(key).pages)
    assert not eng.admit(req)                   # clean rejection, no evict
    assert eng.allocator.state_dict() == before_alloc
    assert eng.prefix.get(key) is not None
    assert eng.prefix.get(key).pages == before_pages
    assert eng.prefix.stats()["evictions"] == 0
    for _ in range(32):                          # drain the blocker
        if blocker.done:
            break
        eng.step()
    assert blocker.done
    assert eng.admit(req)                        # now it shares normally
    assert eng.allocator.refcount(before_pages[0]) == 2
    for _ in range(16):
        if req.done:
            break
        eng.step()
    assert req.done


def test_unregister_prefix_releases_cache_reference():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm)
    rng = np.random.default_rng(8)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    key = eng.register_prefix(prefix)
    req = _mk_requests(cfg, rng, 1, prefix, max_new=4)[0]
    assert eng.admit(req)
    page = eng.prefix.get(key).pages[0]
    assert eng.unregister_prefix(key)
    assert eng.prefix.get(key) is None           # new admits stop matching
    assert eng.allocator.refcount(page) == 1     # in-flight req still maps
    assert not eng.unregister_prefix(key)        # unknown key: no-op False
    for _ in range(8):
        if req.done:
            break
        eng.step()
    assert req.done
    assert eng.allocator.refcount(page) == 0     # last reference released
    assert eng.allocator.free_pages == eng.num_pages - 1


def test_shared_admits_varying_suffix_lengths_one_trace():
    """Suffixes of different lengths pad to one canonical width: every
    shared admit runs the same compiled continuation shape and still
    matches the unshared engine token-for-token."""
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    a = [Request(i, np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, size=tail)]), max_new=4)
        for i, tail in enumerate((1, 3, 5, 7))]
    b = [Request(r.rid, r.prompt.copy(), max_new=r.max_new) for r in a]

    plain = _mk_engine(cfg, prm)
    plain.run(a, max_steps=64)
    shared = _mk_engine(cfg, prm)
    shared.register_prefix(prefix)
    shared.run(b, max_steps=64)

    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out == rb.out, (
            f"request {ra.rid} (suffix {len(ra.prompt) - 16}) diverged")
    assert shared.prefix_stats()["hits"] == 4
    if hasattr(shared._cont_prefill, "_cache_size"):
        assert shared._cont_prefill._cache_size() == 1


# ---------------------------------------------------------------------------
# allocator + engine state round-trips bit-exactly through snapshots
# ---------------------------------------------------------------------------

def _roundtrip_state(alloc):
    clone = PG.PageAllocator(alloc.num_pages)
    clone.load_state(alloc.state_dict())
    return clone


def test_allocator_state_roundtrip_deterministic():
    rng = np.random.default_rng(5)
    names = ["alloc", "share", "free"]
    for trial in range(10):
        ops = [(names[rng.integers(0, 3)], int(rng.integers(0, 100)))
               for _ in range(30)]
        alloc, _ = _drive_allocator(9, ops)
        clone = _roundtrip_state(alloc)
        assert clone.state_dict() == alloc.state_dict()
        # same future: both hand out identical pages in identical order
        assert clone.alloc(2) == alloc.alloc(2)
        assert clone.state_dict() == alloc.state_dict()


@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.tuples(
    st.sampled_from(["alloc", "share", "free"]),
    st.integers(min_value=0, max_value=99)), max_size=40))
def test_allocator_state_roundtrip_property(ops):
    alloc, _ = _drive_allocator(9, ops)
    clone = _roundtrip_state(alloc)
    assert clone.state_dict() == alloc.state_dict()
    assert clone.alloc(1) == alloc.alloc(1)
    assert clone.state_dict() == alloc.state_dict()


def test_allocator_load_state_size_mismatch_raises():
    alloc = PG.PageAllocator(9)
    with pytest.raises(ValueError, match="size mismatch"):
        PG.PageAllocator(5).load_state(alloc.state_dict())


def _chain_leaves(payload):
    """Leaves exactly as ``SnapshotStore.restore`` hands them back: the
    cache tree flattened to keystr-keyed host arrays."""
    flat, _ = jax.tree_util.tree_flatten_with_path(payload["cache"])
    return {jax.tree_util.keystr(path): np.asarray(leaf)
            for path, leaf in flat}


def test_hydrated_engine_decodes_in_lockstep():
    """from_snapshot restores pool + tables + allocator + prefixes +
    in-flight requests exactly: replica decode == producer decode with
    zero replica prefill."""
    cfg, prm = _mk("smollm-135m")
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab_size, size=16)
    producer = _mk_engine(cfg, prm)
    producer.register_prefix(prefix)
    reqs = _mk_requests(cfg, rng, 3, prefix, max_new=12)
    for r in reqs:
        assert producer.admit(r)
    producer.step()                                  # mid-flight snapshot
    leaves = _chain_leaves(producer.snapshot_payload())

    replica = PG.PagedServingEngine.from_snapshot(cfg, prm, leaves)
    assert replica.allocator.state_dict() == producer.allocator.state_dict()
    assert replica.prefix.state_dict() == producer.prefix.state_dict()
    assert replica._chains == producer._chains
    assert replica.prefill_tokens == producer.prefill_tokens
    rep_reqs = [a for a in replica.active if a is not None]
    assert len(rep_reqs) == 3
    for ra, rb in zip(reqs, rep_reqs):
        assert ra.rid == rb.rid and ra.out == rb.out
        assert np.array_equal(ra.prompt, rb.prompt)

    pre = replica.prefill_tokens
    for _ in range(4):                               # lockstep decode
        producer.step()
        replica.step()
    for ra, rb in zip(reqs, rep_reqs):
        assert ra.out == rb.out, f"replica diverged on request {ra.rid}"
    assert replica.prefill_tokens == pre             # no replica prefill


def test_from_snapshot_requires_meta_leaf():
    cfg, prm = _mk("smollm-135m")
    eng = _mk_engine(cfg, prm)
    leaves = {k: v for k, v in _chain_leaves(eng.snapshot_payload()).items()
              if k != "['meta']"}
    with pytest.raises(KeyError, match="meta"):
        PG.PagedServingEngine.from_snapshot(cfg, prm, leaves)


def test_prefix_cache_state_roundtrip():
    cache = PX.PrefixCache()
    cache.add(PX.PrefixEntry(key="a", tokens=np.arange(16, dtype=np.int32),
                             pages=[1, 2]))
    cache.match(np.arange(20))                       # hit, bumps clock
    cache.match(np.arange(5, 25))                    # miss
    clone = PX.PrefixCache()
    clone.load_state(cache.state_dict())
    assert clone.state_dict() == cache.state_dict()
    e = clone.get("a")
    assert e is not None and e.pages == [1, 2]
    assert np.array_equal(e.tokens, np.arange(16))
    assert clone.stats() == cache.stats()
