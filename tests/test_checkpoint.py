"""Checkpointing: 3 in-situ modes, atomicity, retention, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              serialization as ser)
from repro.core.insitu import InSituMode


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (128, 64), jnp.float32)
              .astype(jnp.bfloat16),
              "b": jnp.zeros((64,), jnp.float32)}
    st = optim.init(params, optim.AdamWConfig())
    st = st._replace(mu=jax.tree.map(
        lambda x: x + 0.125, st.mu))
    return {"params": params, "opt": {"mu": st.mu, "nu": st.nu},
            "step": jnp.asarray(3, jnp.int32)}


@pytest.mark.parametrize("mode", list(InSituMode))
def test_roundtrip_all_modes(mode, tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), mode=mode,
                                             every=1, keep=5))
    mgr.save(10, state)
    mgr.wait_idle()
    mgr.finish()
    step, restored = mgr.restore(state)
    assert step == 10
    # weights are bit-exact (lossless path)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"].astype(jnp.float32)),
        np.asarray(state["params"]["w"].astype(jnp.float32)))
    # moments are lossy but error-bounded
    err = float(jnp.max(jnp.abs(
        restored["opt"]["mu"]["w"].astype(jnp.float32)
        - state["opt"]["mu"]["w"].astype(jnp.float32))))
    assert err < 0.05
    assert int(restored["step"]) == 3


def test_v2_layout_single_shard_and_offset_table(tmp_path):
    """Default format: every blob packed into shard files named by an
    offset-table manifest — file count independent of leaf count."""
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(4, state)
    d = tmp_path / "step_000000004"
    files = sorted(os.listdir(d))
    assert files == ["manifest.json", "shard_000.bin"]
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest["format"] == 2
    entries = manifest["leaves"]
    assert len(entries) == 7          # w, b, mu.{w,b}, nu.{w,b}, step
    for ent in entries.values():
        assert set(ent) == {"file", "offset", "bytes", "raw_bytes",
                            "lossy", "bf16"}
        assert ent["file"] == "shard_000.bin"
    # offsets tile the shard exactly: sorted offsets are contiguous
    spans = sorted((e["offset"], e["bytes"]) for e in entries.values())
    pos = 0
    for off, nbytes in spans:
        assert off == pos
        pos += nbytes
    assert pos == (d / "shard_000.bin").stat().st_size


def test_v2_multi_shard_roundtrip(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1,
                                             shard_count=3))
    mgr.save(6, state)
    d = tmp_path / "step_000000006"
    shards = [f for f in os.listdir(d) if f.startswith("shard_")]
    assert 1 < len(shards) <= 3       # byte-balanced upper bound
    step, restored = mgr.restore(state)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"].astype(jnp.float32)),
        np.asarray(state["params"]["w"].astype(jnp.float32)))


def test_v1_format_still_writable_and_restores(tmp_path):
    """format=1 keeps the per-leaf-file layout (benchmark baseline)."""
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1,
                                             format=1, leaf_parallel=False))
    mgr.save(8, state)
    d = tmp_path / "step_000000008"
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest["format"] == 1
    blobs = [f for f in os.listdir(d) if f.endswith(".bin")]
    assert len(blobs) == len(manifest["leaves"])    # one file per leaf
    assert all("offset" not in e for e in manifest["leaves"].values())
    step, restored = mgr.restore(state)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"].astype(jnp.float32)),
        np.asarray(state["params"]["w"].astype(jnp.float32)))


def test_serial_encode_matches_leaf_parallel(tmp_path):
    """leaf_parallel only changes scheduling: stored bytes are identical."""
    state = _state()
    outs = {}
    for name, flag in (("fan", True), ("serial", False)):
        d = tmp_path / name
        mgr = CheckpointManager(CheckpointConfig(str(d), mode=InSituMode.SYNC,
                                                 every=1, leaf_parallel=flag))
        mgr.save(1, state)
        outs[name] = (d / "step_000000001" / "shard_000.bin").read_bytes()
    assert outs["fan"] == outs["serial"]


def test_config_validation_rejects_bad_values(tmp_path):
    with pytest.raises(ValueError, match="every"):
        CheckpointConfig(str(tmp_path), every=0)     # was: ZeroDivisionError
    with pytest.raises(ValueError, match="keep"):
        CheckpointConfig(str(tmp_path), keep=-1)
    with pytest.raises(ValueError, match="format"):
        CheckpointConfig(str(tmp_path), format=3)
    with pytest.raises(ValueError, match="shard_count"):
        CheckpointConfig(str(tmp_path), shard_count=0)


def test_checkpoint_compression_beats_raw(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(1, state)
    rep = mgr.reports[-1]
    assert rep.stored_bytes < rep.raw_bytes
    assert rep.lossy_leaves == 4  # mu.w mu.b nu.w nu.b


def test_retention(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC,
                                             every=1, keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.list_steps() == [3, 4]


def test_atomicity_partial_checkpoint_invisible(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(5, state)
    # simulate a crash mid-save: blobs written, no manifest
    broken = tmp_path / "step_000000009"
    os.makedirs(broken)
    (broken / "deadbeef.bin").write_bytes(b"partial")
    assert mgr.list_steps() == [5]          # 9 is invisible
    step, _ = mgr.restore(state)
    assert step == 5


def test_manifest_metadata_and_restart_counter(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(7, state, meta={"mesh": [1, 1], "arch": "smollm-135m"})
    d = tmp_path / "step_000000007"
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest["meta"]["arch"] == "smollm-135m"
    assert manifest["step"] == 7


def test_elastic_restore_resharding(tmp_path):
    """Restore under a different (1-device) mesh sharding — re-placement."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(2, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    step, restored = mgr.restore(state, shardings=shard)
    w = restored["params"]["w"]
    assert w.sharding == NamedSharding(mesh, P())


def _v1_lossless_frame(arr, codec_name="zlib"):
    """Byte-for-byte pre-chunking (v1) lossless frame."""
    import struct
    import zlib
    comp = {"zlib": lambda b: zlib.compress(b, 6)}[codec_name]
    arr = np.ascontiguousarray(arr)
    raw = arr.tobytes()
    dt = np.dtype(arr.dtype).str.encode()
    return (b"RPRC" + struct.pack("<BBB", 1, 1, len(dt)) + dt
            + struct.pack("<B", arr.ndim)
            + struct.pack(f"<{arr.ndim}q", *arr.shape)
            + struct.pack("<q", len(raw)) + comp(raw))


def test_v1_per_leaf_checkpoint_still_restores(tmp_path):
    """Backward compat: a checkpoint whose blobs are legacy v1 single-stream
    frames (the pre-chunking, per-leaf encoding) restores bit-exactly."""
    import struct

    from repro.core import lossy
    from repro.kernels import ops

    state = _state()
    host = ser.state_to_host(state)
    bf16_keys = {
        k for (p, l) in jax.tree_util.tree_flatten_with_path(state)[0]
        if l is not None and getattr(l, "dtype", None) == jnp.bfloat16
        for k in [jax.tree_util.keystr(p)]}
    encoded = {}
    for key, arr in host.items():
        if ".mu" in key or ".nu" in key or "'mu'" in key or "'nu'" in key:
            # per-leaf lossy frame with v1 *inner* lossless frames; bf16
            # leaves arrive as u16 bit-patterns and go via f32 (same as
            # encode_blobs)
            a = arr
            if key in bf16_keys:
                a = np.asarray(jnp.asarray(arr.view(np.uint16))
                               .view(jnp.bfloat16).astype(jnp.float32))
            c = ops.spectral_compress(jnp.asarray(a, jnp.float32), 1e-2)
            q_blob = _v1_lossless_frame(np.asarray(c.q))
            s_blob = _v1_lossless_frame(np.asarray(c.scale))
            shape = tuple(int(d) for d in c.shape)
            dt = jnp.dtype(c.dtype).name.encode()
            blob = (lossy.LOSSY_MAGIC + struct.pack("<B", len(dt)) + dt
                    + struct.pack("<qB", c.n_elements, len(shape))
                    + struct.pack(f"<{len(shape)}q", *shape)
                    + struct.pack("<qq", len(q_blob), len(s_blob))
                    + q_blob + s_blob)
            ent = {"bytes": len(blob), "lossy": True,
                   "raw_bytes": int(arr.nbytes), "bf16": False}
        else:
            blob = _v1_lossless_frame(arr)
            ent = {"bytes": len(blob), "lossy": False,
                   "raw_bytes": int(arr.nbytes),
                   "bf16": key in bf16_keys}
        encoded[key] = (blob, ent)
    d = str(tmp_path / "step_000000011")
    entries = ser.write_encoded(d, encoded)
    ser.write_manifest(d, 11, entries, {})

    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), every=1))
    step, restored = mgr.restore(state)
    assert step == 11
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"].astype(jnp.float32)),
        np.asarray(state["params"]["w"].astype(jnp.float32)))
    err = float(jnp.max(jnp.abs(
        restored["opt"]["mu"]["w"].astype(jnp.float32)
        - state["opt"]["mu"]["w"].astype(jnp.float32))))
    assert err < 0.05
    assert int(restored["step"]) == 3
    mgr.finish()


def test_resume_after_simulated_failure(tmp_path):
    """New manager over the same dir (a 'restarted job') sees the state."""
    state = _state()
    m1 = CheckpointManager(CheckpointConfig(str(tmp_path),
                                            mode=InSituMode.ASYNC, every=1))
    m1.save(42, state)
    m1.wait_idle()
    m1.finish()
    del m1   # job dies
    m2 = CheckpointManager(CheckpointConfig(str(tmp_path),
                                            mode=InSituMode.ASYNC, every=1))
    assert m2.latest_step() == 42
    step, restored = m2.restore(state)
    assert step == 42
    m2.finish()
