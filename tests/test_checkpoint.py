"""Checkpointing: 3 in-situ modes, atomicity, retention, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import (CheckpointConfig, CheckpointManager,
                              serialization as ser)
from repro.core.insitu import InSituMode


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (128, 64), jnp.float32)
              .astype(jnp.bfloat16),
              "b": jnp.zeros((64,), jnp.float32)}
    st = optim.init(params, optim.AdamWConfig())
    st = st._replace(mu=jax.tree.map(
        lambda x: x + 0.125, st.mu))
    return {"params": params, "opt": {"mu": st.mu, "nu": st.nu},
            "step": jnp.asarray(3, jnp.int32)}


@pytest.mark.parametrize("mode", list(InSituMode))
def test_roundtrip_all_modes(mode, tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), mode=mode,
                                             every=1, keep=5))
    mgr.save(10, state)
    mgr.wait_idle()
    mgr.finish()
    step, restored = mgr.restore(state)
    assert step == 10
    # weights are bit-exact (lossless path)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"].astype(jnp.float32)),
        np.asarray(state["params"]["w"].astype(jnp.float32)))
    # moments are lossy but error-bounded
    err = float(jnp.max(jnp.abs(
        restored["opt"]["mu"]["w"].astype(jnp.float32)
        - state["opt"]["mu"]["w"].astype(jnp.float32))))
    assert err < 0.05
    assert int(restored["step"]) == 3


def test_checkpoint_compression_beats_raw(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(1, state)
    rep = mgr.reports[-1]
    assert rep.stored_bytes < rep.raw_bytes
    assert rep.lossy_leaves == 4  # mu.w mu.b nu.w nu.b


def test_retention(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC,
                                             every=1, keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.list_steps() == [3, 4]


def test_atomicity_partial_checkpoint_invisible(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(5, state)
    # simulate a crash mid-save: blobs written, no manifest
    broken = tmp_path / "step_000000009"
    os.makedirs(broken)
    (broken / "deadbeef.bin").write_bytes(b"partial")
    assert mgr.list_steps() == [5]          # 9 is invisible
    step, _ = mgr.restore(state)
    assert step == 5


def test_manifest_metadata_and_restart_counter(tmp_path):
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(7, state, meta={"mesh": [1, 1], "arch": "smollm-135m"})
    d = tmp_path / "step_000000007"
    manifest = json.loads((d / "manifest.json").read_text())
    assert manifest["meta"]["arch"] == "smollm-135m"
    assert manifest["step"] == 7


def test_elastic_restore_resharding(tmp_path):
    """Restore under a different (1-device) mesh sharding — re-placement."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    state = _state()
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                             mode=InSituMode.SYNC, every=1))
    mgr.save(2, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    step, restored = mgr.restore(state, shardings=shard)
    w = restored["params"]["w"]
    assert w.sharding == NamedSharding(mesh, P())


def test_resume_after_simulated_failure(tmp_path):
    """New manager over the same dir (a 'restarted job') sees the state."""
    state = _state()
    m1 = CheckpointManager(CheckpointConfig(str(tmp_path),
                                            mode=InSituMode.ASYNC, every=1))
    m1.save(42, state)
    m1.wait_idle()
    m1.finish()
    del m1   # job dies
    m2 = CheckpointManager(CheckpointConfig(str(tmp_path),
                                            mode=InSituMode.ASYNC, every=1))
    assert m2.latest_step() == 42
    step, restored = m2.restore(state)
    assert step == 42
    m2.finish()
