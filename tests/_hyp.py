"""Optional-hypothesis shim: property tests run when hypothesis is
installed and are skipped (not collection errors) when it is not.

Usage in a test module:

    from _hyp import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy-construction call chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        if args and callable(args[0]):   # bare @settings
            return args[0]
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # a real def (pytest refuses to collect marked lambdas), with
            # no parameters so pytest doesn't demand the strategy kwargs
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
