"""InSituEngine semantics: the paper's Fig. 1 contract, measured.

  SYNC   — task runs on the loop thread; loop time includes it.
  ASYNC  — loop only pays the hand-off; task runs on insitu-* threads
           concurrently with subsequent steps.
  Backpressure — a slow consumer stalls the producer once the ring fills
           (the F3 regime).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (InSituEngine, InSituMode, InSituTask, StagedItem,
                        StagingBuffer, Telemetry, run_workflow)
from repro.core.allocator import Allocator, AmdahlModel
from repro.core.staging import Closed


def _engine(mode, task_s=0.02, every=1, p_i=2, cap=4):
    def work(step, payload):
        time.sleep(task_s)
        return ("done", step)

    return InSituEngine(
        [InSituTask("t", "x", work, mode=mode, every=every)],
        p_i=p_i, staging_capacity=cap)


def _run(engine, n=6, step_s=0.01):
    def app_step(i):
        time.sleep(step_s)   # a TPU-like device step: host-idle wait
        return {"x": lambda: np.zeros(8)}

    return run_workflow(n, app_step, engine)


def test_sync_runs_on_loop_thread():
    eng = _engine(InSituMode.SYNC)
    _run(eng)
    assert len(eng.results) == 6
    assert all(r.worker == threading.main_thread().name for r in eng.results)
    assert eng.telemetry.total("insitu-sync/") > 0
    assert eng.telemetry.total("insitu-async/") == 0


def test_async_runs_on_workers_and_overlaps():
    eng = _engine(InSituMode.ASYNC, task_s=0.03)
    t0 = time.perf_counter()
    _run(eng, n=6, step_s=0.03)
    wall = time.perf_counter() - t0
    assert len(eng.results) == 6
    assert all(r.worker.startswith("insitu-") for r in eng.results)
    # serial would be >= 6*(0.03+0.03) = 0.36; overlap must beat it
    assert wall < 0.33, f"no overlap: wall={wall:.3f}s"
    assert eng.telemetry.total("insitu-sync/") == 0


def test_async_backpressure_recorded():
    eng = _engine(InSituMode.ASYNC, task_s=0.05, p_i=1, cap=1)
    _run(eng, n=8, step_s=0.001)
    # ring of 1 with slow consumer -> producer must have waited
    assert eng.telemetry.total("staging/wait") > 0
    assert len(eng.results) == 8


def test_every_n_steps():
    eng = _engine(InSituMode.ASYNC, task_s=0.0, every=3)
    _run(eng, n=9)
    assert sorted(r.step for r in eng.results) == [0, 3, 6]


def test_worker_errors_captured_not_fatal():
    def bad(step, payload):
        raise RuntimeError("boom")

    eng = InSituEngine([InSituTask("bad", "x", bad, InSituMode.ASYNC)], p_i=1)
    _run(eng, n=3)
    assert len(eng.errors) == 3
    assert len(eng.results) == 0


def test_lazy_providers_only_called_when_fired():
    calls = []

    def app_step(i):
        return {"x": lambda: calls.append(i) or np.zeros(2)}

    eng = _engine(InSituMode.ASYNC, task_s=0.0, every=5)
    run_workflow(10, app_step, eng)
    assert calls == [0, 5]


# -- staging ring -------------------------------------------------------------

def test_staging_fifo_and_close():
    buf = StagingBuffer(capacity=3)
    for i in range(3):
        buf.put(StagedItem(i, "a", i))
    assert [buf.get().payload for _ in range(3)] == [0, 1, 2]
    buf.close()
    with pytest.raises(Closed):
        buf.get(timeout=0.01)
    with pytest.raises(Closed):
        buf.put(StagedItem(9, "a", 9))


def test_staging_try_put_drop_policy():
    buf = StagingBuffer(capacity=1)
    assert buf.try_put(StagedItem(0, "a", 0))
    assert not buf.try_put(StagedItem(1, "a", 1))


def _timed_consumer(buf, out):
    try:
        item = buf.get()
        out.append(("item", item.payload, time.perf_counter()))
    except Closed:
        out.append(("closed", None, time.perf_counter()))


def test_staging_get_wakes_immediately_on_put():
    """Condition-driven ring: no 0.1 s poll loop between put and wake-up."""
    buf = StagingBuffer(capacity=2)
    out = []
    th = threading.Thread(target=_timed_consumer, args=(buf, out))
    th.start()
    time.sleep(0.05)                      # consumer is parked in get()
    t_put = time.perf_counter()
    buf.put(StagedItem(0, "a", 42))
    th.join(timeout=5)
    kind, payload, t_wake = out[0]
    assert (kind, payload) == ("item", 42)
    assert t_wake - t_put < 0.05, f"woke after {t_wake - t_put:.3f}s"


def test_staging_close_wakes_blocked_consumer_immediately():
    buf = StagingBuffer(capacity=2)
    out = []
    th = threading.Thread(target=_timed_consumer, args=(buf, out))
    th.start()
    time.sleep(0.05)
    t_close = time.perf_counter()
    buf.close()
    th.join(timeout=5)
    kind, _, t_wake = out[0]
    assert kind == "closed"
    assert t_wake - t_close < 0.05, f"woke after {t_wake - t_close:.3f}s"


def test_staging_blocked_producer_raises_on_close():
    buf = StagingBuffer(capacity=1)
    buf.put(StagedItem(0, "a", 0))        # ring now full
    errs = []

    def producer():
        try:
            buf.put(StagedItem(1, "a", 1))
        except Closed:
            errs.append("closed")

    th = threading.Thread(target=producer)
    th.start()
    time.sleep(0.05)
    buf.close()
    th.join(timeout=5)
    assert errs == ["closed"]


# -- allocator (Table I / F1 / F6) ---------------------------------------------

def test_amdahl_fit():
    m = AmdahlModel()
    for p in (1, 2, 4, 8):
        m.observe(p, 1.0 + 8.0 / p)
    assert m.serial == pytest.approx(1.0, abs=0.05)
    assert m.parallel == pytest.approx(8.0, rel=0.05)


def test_allocator_balances_app_and_task():
    """F1: optimal async split puts both sides at roughly equal duration."""
    al = Allocator(p_total=72)
    for p in (18, 36, 72):
        al.observe_app(p, 10.0 / p)        # app scales well
        al.observe_task(p, 0.05 + 2.0 / p)  # task scales worse
    plan = al.plan(n_steps=100, every=5)
    assert plan.mode == "async"
    assert al.balance_quality(plan) < 0.35
    assert plan.p_app + plan.p_insitu == 72


def test_allocator_prefers_sync_for_cheap_tasks():
    """F6: when the task is trivially cheap, sync wins (no staging tax)."""
    al = Allocator(p_total=8, handoff_s=0.5)
    al.observe_app(8, 1.0)
    al.observe_task(8, 1e-4)
    al.observe_task(1, 1e-3)
    plan = al.plan(n_steps=100, every=1)
    assert plan.mode == "sync"
