"""Fault tolerance: heartbeats, stragglers, remesh planning, the fault
preset, sink retry/degrade, the time-budget Adaptive trigger, and the
mesh-level kill-point (subprocess, multi-device XLA platform)."""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.runtime import (PipelineRuntime, PipelineTask, Placement,
                                TransientError)
from repro.core.session import (Adaptive, InSituPlan, InSituTaskError,
                                PlanError, Session, TaskSpec)
from repro.distributed.fault import (ElasticRestore, FaultController,
                                     HeartbeatTracker, StragglerMonitor,
                                     merge_model_shards, plan_elastic_remesh)

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


# ---------------------------------------------------------------------------
# HeartbeatTracker on a fake clock
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_heartbeat_seeds_last_seen_from_injected_clock():
    # regression: seeding from time.monotonic() while driving with a
    # near-zero injected clock declared every host dead at t=0
    clk = FakeClock(5.0)
    hb = HeartbeatTracker([0, 1], grace_s=2.0, clock=clk)
    assert hb.failed_hosts() == []
    assert hb.alive_hosts() == [0, 1]


def test_heartbeat_grace_transitions_on_fake_clock():
    clk = FakeClock(0.0)
    hb = HeartbeatTracker([0, 1, 2], grace_s=3.0, clock=clk)
    clk.t = 2.0
    hb.beat(1)
    hb.beat(2)
    clk.t = 4.0          # host 0 last seen at 0 -> 4s silent > 3s grace
    assert hb.failed_hosts() == [0]
    assert hb.alive_hosts() == [1, 2]
    clk.t = 6.0          # hosts 1/2 now 4s silent too
    assert hb.failed_hosts() == [0, 1, 2]
    hb.beat(0)           # a failed host that beats again is alive
    assert hb.failed_hosts() == [1, 2]


def test_heartbeat_explicit_now_still_wins():
    hb = HeartbeatTracker([0], grace_s=1.0, clock=FakeClock(0.0))
    hb.beat(0, now=10.0)
    assert hb.failed_hosts(now=10.5) == []
    assert hb.failed_hosts(now=12.0) == [0]


# ---------------------------------------------------------------------------
# StragglerMonitor thresholds and EWMA
# ---------------------------------------------------------------------------

def test_straggler_ewma_converges():
    mon = StragglerMonitor(alpha=0.5)
    mon.observe(0, 1.0)
    assert mon.ewma[0] == 1.0           # first sample seeds the EWMA
    mon.observe(0, 2.0)
    assert mon.ewma[0] == pytest.approx(1.5)
    mon.observe(0, 2.0)
    assert mon.ewma[0] == pytest.approx(1.75)


def test_straggler_flags_and_mitigation_tiers():
    mon = StragglerMonitor(alpha=1.0, factor=1.5)
    for h in range(4):
        mon.observe(h, 1.0)
    assert mon.stragglers() == []
    assert mon.mitigation(0) == "none"
    mon.observe(3, 2.0)                 # 2.0 > 1.5 x median(1.0)
    assert mon.stragglers() == [3]
    assert mon.mitigation(3) == "reduce_insitu_pi"
    mon.observe(3, 4.0)                 # 4.0 > 2 x 1.5 x median
    assert mon.mitigation(3) == "replace_at_checkpoint"
    assert mon.mitigation(0) == "none"


# ---------------------------------------------------------------------------
# plan_elastic_remesh: examples + properties
# ---------------------------------------------------------------------------

def test_remesh_prefers_smallest_merge_factor_on_ties():
    # 2 survivors of a (2, 2): (1, 2) with f=1 and (2, 1) with f=2 both
    # keep 2 devices; the deterministic tie-break picks f=1
    plan = plan_elastic_remesh((2, 2), ("data", "model"), 2)
    assert plan.new_shape == (1, 2)
    assert plan.model_merge_factor == 1


def test_remesh_non_power_of_two_model_axis():
    # model=6 can shrink to 3 now (divisor 2 was impossible with the
    # hardcoded [1, 2, 4, 8, 16] list): 9 survivors -> (3, 3), not (1, 6)
    plan = plan_elastic_remesh((4, 6), ("data", "model"), 9)
    assert plan.new_shape == (3, 3)
    assert plan.model_merge_factor == 2
    assert plan.new_device_count == 9


def test_remesh_pod_axis_shrinks_by_whole_pods():
    plan = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"), 300)
    pod, data, model = plan.new_shape
    assert pod in (1, 2)
    assert pod * data * model <= 300


def test_remesh_raises_when_nothing_fits():
    with pytest.raises(ValueError):
        plan_elastic_remesh((2, 2), ("data", "model"), 0)


def test_remesh_shard_sources():
    plan = plan_elastic_remesh((4, 8), ("data", "model"), 8)
    # 8 survivors: (1, 8) f=1 beats (2, 4) f=2 on the tie-break? No —
    # (2, 4) has 8 devices too; smallest f wins at equal count: f=1
    assert plan.model_merge_factor == 1
    assert list(plan.shard_sources(3)) == [3]
    plan2 = plan_elastic_remesh((1, 8), ("data", "model"), 2)
    assert plan2.new_shape == (1, 2)
    assert plan2.model_merge_factor == 4
    assert list(plan2.shard_sources(1)) == [4, 5, 6, 7]


@settings(max_examples=80, deadline=None)
@given(data=st.integers(1, 32), model=st.integers(1, 32),
       survivors=st.integers(1, 1024))
def test_remesh_properties_2d(data, model, survivors):
    try:
        plan = plan_elastic_remesh((data, model), ("data", "model"),
                                   survivors)
    except ValueError:
        # nothing fits only when even (1, 1) doesn't
        assert survivors < 1
        return
    d, m = plan.new_shape
    assert d * m <= survivors                 # never exceeds the survivors
    assert model % m == 0                     # model divides the old axis
    assert plan.model_merge_factor == model // m


@settings(max_examples=60, deadline=None)
@given(pod=st.integers(1, 4), data=st.integers(1, 16),
       model=st.integers(1, 16), survivors=st.integers(1, 512))
def test_remesh_properties_3d(pod, data, model, survivors):
    try:
        plan = plan_elastic_remesh((pod, data, model),
                                   ("pod", "data", "model"), survivors)
    except ValueError:
        assert survivors < 1
        return
    p, d, m = plan.new_shape
    assert p * d * m <= survivors
    assert 1 <= p <= pod                      # whole pods only
    assert model % m == 0


def test_merge_model_shards():
    shards = [np.full((2, 3), i, np.float32) for i in range(4)]
    merged = merge_model_shards(shards, 2, axis=0)
    assert len(merged) == 2
    assert merged[0].shape == (4, 3)
    np.testing.assert_array_equal(merged[1][:2], shards[2])
    np.testing.assert_array_equal(merged[1][2:], shards[3])
    with pytest.raises(ValueError):
        merge_model_shards(shards, 3)
    with pytest.raises(ValueError):
        merge_model_shards(shards, 0)


# ---------------------------------------------------------------------------
# FaultController: ingest, escalation, shedding
# ---------------------------------------------------------------------------

def test_controller_ingest_detects_failed_host():
    clk = FakeClock(0.0)
    ctrl = FaultController([0, 1], grace_s=2.0, clock=clk)
    for i in range(5):
        clk.t = float(i)
        beats = {0: 0.1} if i >= 2 else {0: 0.1, 1: 0.1}
        out = ctrl.ingest(i, {"hosts": beats})
    assert out["failed_hosts"] == [1]
    assert ctrl.report()["alive_hosts"] == [0]


def test_controller_payload_forms():
    ctrl = FaultController([0, 1], clock=FakeClock(0.0))
    ctrl.ingest(0, {"host": 0, "step_s": 0.5})
    ctrl.ingest(0, {"hosts": {1: 0.25}})
    ctrl.ingest(0, {0: 0.5, 1: 0.25})
    assert set(ctrl.monitor.ewma) == {0, 1}
    with pytest.raises(ValueError):
        ctrl.ingest(0, "not a mapping")


def test_controller_escalates_once_per_transition():
    ctrl = FaultController([0, 1, 2], alpha=1.0, factor=1.5,
                           clock=FakeClock(0.0))
    base = {0: 1.0, 1: 1.0}
    ctrl.ingest(0, {"hosts": {**base, 2: 1.0}})
    assert ctrl.shed_events == 0
    ctrl.ingest(1, {"hosts": {**base, 2: 2.0}})      # -> reduce_insitu_pi
    assert ctrl.shed_events == 1
    assert ctrl.mitigations[2] == "reduce_insitu_pi"
    ctrl.ingest(2, {"hosts": {**base, 2: 2.0}})      # same tier: no re-shed
    assert ctrl.shed_events == 1
    ctrl.ingest(3, {"hosts": {**base, 2: 8.0}})      # -> replace
    assert ctrl.shed_events == 2
    assert ctrl.replace_candidates == {2}
    ctrl.ingest(4, {"hosts": {**base, 2: 1.0}})      # recovers
    assert 2 not in ctrl.mitigations


# ---------------------------------------------------------------------------
# The fault preset wired into a Session
# ---------------------------------------------------------------------------

def _fault_plan(extra_tasks=None, **opts):
    options = {"hosts": [0, 1], "grace_s": 2.0, "alpha": 1.0,
               "factor": 1.5, **opts}
    tasks = {"fault": {"stream": "health", "preset": "fault", "every": 1,
                       "placement": "sync", "pipelined": False,
                       "options": options}}
    tasks.update(extra_tasks or {})
    streams = sorted({t["stream"] for t in tasks.values()})
    return {"streams": streams, "workers": 2, "tasks": tasks}


def test_fault_preset_validates_options():
    with pytest.raises(PlanError, match="hosts"):
        Session(_fault_plan(hosts=[]))
    with pytest.raises(PlanError, match="unknown fault option"):
        Session(_fault_plan(bogus=1))


def test_fault_preset_heartbeats_on_session_clock():
    clk = FakeClock(0.0)
    with Session(_fault_plan(), clock=lambda: clk.t) as s:
        ctrl = s.fault_controller()
        for i in range(6):
            clk.t = float(i)
            beats = {0: 0.1} if i >= 2 else {0: 0.1, 1: 0.1}
            s.emit("health", i, {"hosts": beats})
        assert ctrl.failed_hosts() == [1]
    rep = s.report()
    assert rep["fault"]["failed_hosts"] == [1]
    assert rep["fault"]["alive_hosts"] == [0]
    assert 0 in rep["fault"]["straggler_ewma"]


def test_fault_preset_sheds_insitu_load_before_replacing():
    # a straggler first widens the other tasks' cadence (never its own,
    # never the checkpoint's), then joins the replace candidates
    extra = {"analytics": {"stream": "x", "preset": "spectra",
                           "every": 2, "placement": "sync",
                           "pipelined": False}}
    plan = _fault_plan(extra_tasks=extra, hosts=[0, 1, 2])
    clk = FakeClock(0.0)
    with Session(plan, clock=lambda: clk.t) as s:
        ctrl = s.fault_controller("fault")
        assert s.runtime.effective_every("analytics") == 2
        s.emit("health", 0, {"hosts": {0: 1.0, 1: 1.0, 2: 1.0}})
        s.emit("health", 1, {"hosts": {0: 1.0, 1: 1.0, 2: 2.0}})  # 2 lags
        assert ctrl.shed_events == 1
        assert s.runtime.effective_every("analytics") == 4   # widened
        assert s.runtime.effective_every("fault") == 1       # not itself
        assert ctrl.widened == {"analytics": 4}
        s.emit("health", 2, {"hosts": {0: 1.0, 1: 1.0, 2: 9.0}})  # escalates
        assert ctrl.report()["replace_at_checkpoint"] == [2]
    rep = s.report()
    assert rep["fault"]["shed_events"] == 2
    assert rep["fault"]["mitigations"] == {2: "replace_at_checkpoint"}


def test_fault_controller_lookup_errors():
    with Session(_fault_plan()) as s:
        with pytest.raises(PlanError):
            s.fault_controller("nope")
    plan = InSituPlan.from_dict({"streams": ["x"], "tasks": {
        "t": {"stream": "x", "preset": "spectra"}}})
    with Session(plan) as s:
        with pytest.raises(PlanError):
            s.fault_controller()


# ---------------------------------------------------------------------------
# Sink retry / degrade on the runtime
# ---------------------------------------------------------------------------

def _runtime_with_task(**kw):
    rt = PipelineRuntime(workers=2, staging_capacity=4)
    calls = []

    def sink(step, payload):
        calls.append(step)
        return step

    task = PipelineTask(name="t", source="s", sink=sink,
                        placement=Placement.SYNC, pipelined=False,
                        retry_backoff_s=0.0, **kw)
    rt.register(task)
    return rt, calls


def test_transient_sink_failure_retries_then_succeeds():
    rt, calls = _runtime_with_task(retries=3)
    fails = [2]

    def fault(step):
        if fails[0] > 0:
            fails[0] -= 1
            raise TransientError("flaky IO")

    rt.inject_sink_fault("t", fault)
    rt.submit(0, {"s": lambda: 1})
    rt.drain()
    assert calls == [0]                      # the sink ultimately ran
    assert rt.retry_counts["t"] == 2
    assert rt.degraded == {}
    assert rt.errors == []


def test_exhausted_retries_degrade_and_drop_instead_of_raising():
    rt, calls = _runtime_with_task(retries=2)
    rt.inject_sink_fault("t", lambda step: (_ for _ in ()).throw(
        TransientError("dead disk")))
    for step in range(4):
        rt.submit(step, {"s": lambda: step})
    rt.drain()
    assert calls == []
    assert rt.errors == []                   # degraded, never raised
    deg = rt.degraded["t"]
    assert deg["step"] == 0 and deg["dropped"] == 3
    assert rt.retry_counts["t"] == 2
    rep = rt.report()
    assert rep["degraded"]["t"]["dropped"] == 3
    assert rep["retries"]["t"] == 2


def test_clearing_fault_hook_does_not_resurrect_degraded_task():
    rt, calls = _runtime_with_task(retries=0)
    rt.inject_sink_fault("t", lambda step: (_ for _ in ()).throw(
        TransientError("boom")))
    rt.submit(0, {"s": lambda: 0})
    rt.inject_sink_fault("t", None)          # IO recovers...
    rt.submit(1, {"s": lambda: 1})           # ...but the task stays degraded
    rt.drain()
    assert calls == []
    assert rt.degraded["t"]["dropped"] == 1


def test_permanent_sink_failure_still_raises_through_finish():
    # only TransientError degrades; a permanent failure keeps the existing
    # captured-error path and surfaces with stream/task/step context
    plan = InSituPlan.from_dict({"streams": ["x"], "tasks": {
        "t": {"stream": "x", "preset": "spectra", "placement": "async",
              "retries": 5}}})
    s = Session(plan)
    s.runtime.inject_sink_fault(
        "t", lambda step: (_ for _ in ()).throw(RuntimeError("perm")))
    s.emit("x", 3, np.ones(4, np.float32))
    with pytest.raises(InSituTaskError, match=r"'t'.*'x'.*step 3"):
        s.finish(raise_on_error=True)
    assert s.runtime.degraded == {}          # degraded is for transients


def test_transient_error_from_sink_itself_degrades():
    rt = PipelineRuntime(workers=1, staging_capacity=2)
    rt.register(PipelineTask(
        name="t", source="s", placement=Placement.SYNC, pipelined=False,
        retries=1, retry_backoff_s=0.0,
        sink=lambda step, p: (_ for _ in ()).throw(
            TransientError("sink-side"))))
    rt.submit(0, {"s": lambda: 0})
    rt.drain()
    assert rt.errors == []
    assert rt.degraded["t"]["retries"] == 1


def test_retry_backoff_is_capped_exponential():
    rt = PipelineRuntime(workers=1, staging_capacity=2)
    sleeps = []
    rt._sleep = sleeps.append
    rt.register(PipelineTask(
        name="t", source="s", placement=Placement.SYNC, pipelined=False,
        retries=6, retry_backoff_s=0.5,
        sink=lambda step, p: (_ for _ in ()).throw(TransientError("x"))))
    rt.submit(0, {"s": lambda: 0})
    rt.drain()
    assert sleeps == [0.5, 1.0, 2.0, 2.0, 2.0, 2.0]   # capped at 2s


def test_plan_validates_retry_fields():
    base = {"streams": ["x"], "tasks": {
        "t": {"stream": "x", "preset": "spectra", "retries": -1}}}
    with pytest.raises(PlanError, match="retries"):
        InSituPlan.from_dict(base)
    base["tasks"]["t"] = {"stream": "x", "preset": "spectra",
                          "retry_backoff_s": -0.1}
    with pytest.raises(PlanError, match="retry_backoff_s"):
        InSituPlan.from_dict(base)


# ---------------------------------------------------------------------------
# Time-budget Adaptive trigger
# ---------------------------------------------------------------------------

def test_adaptive_budget_round_trips_through_dict():
    trig = Adaptive(2, max_every=16, after=3, budget_s=0.25)
    d = trig.to_dict()["trigger"]
    assert d["budget_s"] == 0.25
    plan = InSituPlan.from_dict({"streams": ["x"], "tasks": {
        "t": {"stream": "x", "preset": "spectra", "trigger": d}}})
    assert plan.tasks[0].trigger == trig
    assert plan.to_dict()["tasks"]["t"]["trigger"]["budget_s"] == 0.25


def test_adaptive_budget_validation():
    with pytest.raises(PlanError, match="budget_s"):
        InSituPlan.from_dict({"streams": ["x"], "tasks": {
            "t": {"stream": "x", "preset": "spectra",
                  "trigger": {"kind": "adaptive", "n": 1,
                              "budget_s": 0}}}})


def test_budget_widen_after_consecutive_over_budget_firings():
    plan = InSituPlan(streams=["x"], tasks=[
        TaskSpec(name="slow", stream="x",
                 trigger=Adaptive(1, max_every=8, after=2, budget_s=0.005),
                 placement=Placement.SYNC, pipelined=False,
                 sink=lambda step, p: time.sleep(0.02))])
    with Session(plan) as s:
        for i in range(3):
            s.emit("x", i, {"v": 1})
        # two consecutive over-budget sync firings -> period doubles
        assert s.runtime.effective_every("slow") == 2
        assert s.runtime.telemetry.counters()["budget/adapt/slow"] >= 1
    assert s.report()["effective_every"]["slow"] >= 2


def test_budget_under_budget_firings_reset_the_streak():
    rt = PipelineRuntime(workers=1, staging_capacity=2)
    rt.register(PipelineTask(
        name="t", source="s", placement=Placement.SYNC, pipelined=False,
        budget_s=10.0, adapt_after=2,
        sink=lambda step, p: p))
    for i in range(8):
        rt.submit(i, {"s": lambda: 1})
    rt.drain()
    assert rt.effective_every("t") == 1      # never over budget


def test_widen_every_caps():
    rt = PipelineRuntime(workers=1, staging_capacity=2)
    rt.register(PipelineTask(name="t", source="s", sink=lambda s_, p: p,
                             adapt_max_every=4))
    assert rt.widen_every("t") is True       # 1 -> 2
    assert rt.widen_every("t") is True       # 2 -> 4
    assert rt.widen_every("t") is False      # capped
    assert rt.effective_every("t") == 4
    rt.drain()


# ---------------------------------------------------------------------------
# Elastic restore plumbing (single-device; the multi-device path is the
# subprocess kill-point below)
# ---------------------------------------------------------------------------

def test_elastic_restore_requires_mesh_meta(tmp_path):
    import jax
    import jax.numpy as jnp
    plan = {"streams": ["state"], "tasks": {
        "checkpoint": {"stream": "state", "preset": "checkpoint",
                       "every": 1, "placement": "sync",
                       "options": {"directory": str(tmp_path)}}}}
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    with Session(plan) as s:
        s.emit("state", 0, state)
    with Session(plan) as s:
        with pytest.raises(PlanError, match="mesh geometry"):
            s.restore(state, elastic=True, devices=jax.devices())


def test_elastic_restore_single_device_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    plan = {"streams": ["state"], "tasks": {
        "checkpoint": {"stream": "state", "preset": "checkpoint",
                       "every": 1, "placement": "sync",
                       "options": {"directory": str(tmp_path)}}}}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    with Session(plan) as s:
        s.set_checkpoint_meta(mesh=mesh)
        s.emit("state", 2, state)
    # the manifest carries the mesh geometry the elastic path plans from
    mgr_meta = s.checkpoint.read_meta()
    assert mgr_meta["mesh"] == {"shape": [1, 1], "axes": ["data", "model"]}
    with Session(plan) as s:
        step, restored = s.restore(state, elastic=True,
                                   devices=jax.devices()[:1])
        assert step == 2
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.arange(8, dtype=np.float32))
        rm = s.remesh
        assert isinstance(rm, ElasticRestore)
        assert rm.step == 2
        assert rm.plan.new_shape == (1, 1)
        assert tuple(rm.mesh.axis_names) == ("data", "model")
    assert s.remesh is rm


# ---------------------------------------------------------------------------
# The headline: mesh-level kill-point (subprocess, 8 fake devices)
# ---------------------------------------------------------------------------

def test_killpoint_host_drop_resumes_on_remeshed_grid(tmp_path):
    """Drop a host mid-run; the run continues on the remeshed grid and
    final losses match the golden non-failed run within lossy bounds."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(TESTS_DIR), "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(TESTS_DIR, "killpoint_driver.py"),
         "--steps", "9", "--fail-at", "4", "--ckpt-every", "2",
         "--ckpt-dir", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    assert out["failed_hosts"] == [1]
    assert out["fault_report"]["alive_hosts"] == [0]
    assert out["detect_step"] >= 4           # after the grace window
    # remesh: 2 surviving devices, model axis kept (f=1 beats f=2 on ties)
    assert out["new_shape"] == [1, 2]
    assert out["merge_factor"] == 1
    assert out["restored_step"] <= out["detect_step"]

    golden = out["golden_losses"]
    resumed = {int(k): v for k, v in out["resumed_losses"].items()}
    assert resumed, "no resumed steps"
    assert max(resumed) == len(golden) - 1   # ran to completion
    for i, loss in resumed.items():
        # lossy bound: checkpointed moments are spectral-compressed, so
        # the resumed trajectory drifts slightly from golden
        assert abs(loss - golden[i]) <= max(0.05, 0.02 * abs(golden[i])), (
            i, loss, golden[i])
