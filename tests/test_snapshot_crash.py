"""Crash consistency: kill-point tests over the snapshot chain protocol.

The publish protocol is frame bytes -> tmp file (fsynced) -> rename in ->
dir fsync, one file per frame, so a reader can only ever observe complete
published frames. These tests simulate a crash at each stage — by
reconstructing the exact on-disk debris that stage leaves behind — and
assert that restore either replays the published prefix bit-identically or
raises :class:`SnapshotCorruptError` naming the chain position, mirroring
``tests/test_checkpoint_crash.py``.
"""
import os

import numpy as np
import pytest

from repro.serving.snapshot import SnapshotCorruptError, SnapshotStore

BASE_EVERY = 3
N_FRAMES = 8          # bases at seq 0, 3, 6


def _publish_chain(directory, n=N_FRAMES, seed=0):
    """Publish an append-mostly chain; returns the per-seq snapshots."""
    rng = np.random.default_rng(seed)
    slab = {"k": rng.standard_normal(20000).astype(np.float32),
            "v": rng.standard_normal(20000).astype(np.float32)}
    store = SnapshotStore(str(directory), base_every=BASE_EVERY,
                          chunk_bytes=1 << 12)
    snaps = []
    for i in range(n):
        at = int(rng.integers(0, 19000))
        for arr in slab.values():
            arr[at:at + 1000] = rng.standard_normal(1000)
        store.publish("kv", i, slab)
        snaps.append({k: a.copy() for k, a in slab.items()})
    return snaps


def _frames(directory):
    d = os.path.join(str(directory), "kv")
    return sorted(os.path.join(d, f) for f in os.listdir(d)
                  if f.endswith(".snap"))


def _assert_restores(directory, want, upto=None):
    store = SnapshotStore(str(directory), base_every=BASE_EVERY)
    step, leaves = store.restore("kv", upto=upto)
    for key, arr in want.items():
        np.testing.assert_array_equal(leaves[f"['{key}']"], arr)
    return step


# -- kill point 1: crash between publishes (any prefix is a valid chain) ------

def test_restore_succeeds_from_every_published_prefix(tmp_path):
    snaps = _publish_chain(tmp_path)
    files = _frames(tmp_path)
    assert len(files) == N_FRAMES
    # simulate the crash after frame k by removing everything newer
    for k in reversed(range(N_FRAMES)):
        for f in files[k + 1:]:
            if os.path.exists(f):
                os.remove(f)
        assert _assert_restores(tmp_path, snaps[k], upto=None) == k


# -- kill point 2: crash mid-write, tmp file never renamed in -----------------

def test_unrenamed_tmp_frame_is_invisible(tmp_path):
    snaps = _publish_chain(tmp_path)
    d = os.path.join(str(tmp_path), "kv")
    # a torn half-frame that never reached its rename
    with open(os.path.join(d, f".tmp_frame_{N_FRAMES:08d}"), "wb") as f:
        f.write(b"RPSS\x01garbage-that-never-got-renamed")
    assert _assert_restores(tmp_path, snaps[-1]) == N_FRAMES - 1
    # a restarted writer appends past the debris and the chain stays whole
    store = SnapshotStore(str(tmp_path), base_every=BASE_EVERY,
                          chunk_bytes=1 << 12)
    store.publish("kv", 99, snaps[-1])
    step, _ = store.restore("kv")
    assert step == 99


# -- corruption: truncated / bit-flipped / missing frames ---------------------

def test_truncated_tail_frame_names_chain_position(tmp_path):
    _publish_chain(tmp_path)
    victim = _frames(tmp_path)[-1]              # seq 7, a delta
    blob = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(SnapshotCorruptError,
                       match=r"chain position 7.*crc"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")


def _flip_bit(path):
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))


def test_bitflipped_delta_names_chain_position(tmp_path):
    snaps = _publish_chain(tmp_path)            # bases at seqs 0, 3, 6
    _flip_bit(_frames(tmp_path)[7])             # delta in the LIVE chain
    with pytest.raises(SnapshotCorruptError, match="chain position 7"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")
    # a prefix that stops before the damage still restores
    _assert_restores(tmp_path, snaps[6], upto=6)


def test_damage_behind_the_live_base_does_not_block_restore(tmp_path):
    """A corrupted frame in a *retired* chain (behind the newest base) is
    dead weight: the live chain replays regardless."""
    snaps = _publish_chain(tmp_path)            # live chain: base 6, delta 7
    _flip_bit(_frames(tmp_path)[4])
    assert _assert_restores(tmp_path, snaps[-1]) == N_FRAMES - 1
    # ...while explicitly replaying the damaged prefix still raises
    with pytest.raises(SnapshotCorruptError, match="chain position 4"):
        SnapshotStore(str(tmp_path),
                      base_every=BASE_EVERY).restore("kv", upto=5)


def test_missing_middle_delta_is_a_chain_gap(tmp_path):
    _publish_chain(tmp_path)                    # bases at seqs 0, 3, 6
    files = _frames(tmp_path)
    os.remove(files[7])                         # tail delta gone...
    store = SnapshotStore(str(tmp_path), base_every=BASE_EVERY)
    step, _ = store.restore("kv")               # ...chain up to base 6 whole
    assert step == 6
    # now lose base 6 AND delta 4: the newest base is 3 and its chain has
    # a hole at position 4 — replay must refuse, naming the missing frame
    os.remove(files[6])
    os.remove(files[4])
    with pytest.raises(SnapshotCorruptError,
                       match=r"chain position 4.*missing"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")


def test_chain_without_base_raises(tmp_path):
    _publish_chain(tmp_path, n=3)               # base at 0, deltas 1-2
    os.remove(_frames(tmp_path)[0])
    with pytest.raises(SnapshotCorruptError, match="no base frame"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")


def test_bitflipped_header_field_is_detected(tmp_path):
    """The crc covers the header too: a flipped bit in the step field (or
    n_leaves) must not validate and silently restore wrong metadata."""
    _publish_chain(tmp_path, n=2)
    victim = _frames(tmp_path)[1]
    blob = bytearray(open(victim, "rb").read())
    blob[15] ^= 0x01            # inside the header's step field
    with open(victim, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(SnapshotCorruptError,
                       match=r"chain position 1.*crc"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")


def test_wrong_magic_frame_is_corrupt(tmp_path):
    _publish_chain(tmp_path, n=2)
    victim = _frames(tmp_path)[1]
    blob = bytearray(open(victim, "rb").read())
    blob[:4] = b"XXXX"
    with open(victim, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(SnapshotCorruptError, match="magic"):
        SnapshotStore(str(tmp_path), base_every=BASE_EVERY).restore("kv")


# -- writer restart over damaged chains ---------------------------------------

def test_restarted_writer_rebases_over_a_corrupt_chain(tmp_path):
    """A writer that cannot reconstruct the previous snapshot from disk
    must open a fresh chain (next publish is a base), and restore then
    succeeds through the new base regardless of the damage behind it."""
    snaps = _publish_chain(tmp_path)
    victim = _frames(tmp_path)[-1]
    blob = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(blob[: len(blob) // 2])
    store = SnapshotStore(str(tmp_path), base_every=BASE_EVERY,
                          chunk_bytes=1 << 12)
    rec = store.publish("kv", 100, snaps[-1])
    assert rec.kind == "base"                   # rebased, not chained
    step, leaves = store.restore("kv")
    assert step == 100
    for key, arr in snaps[-1].items():
        np.testing.assert_array_equal(leaves[f"['{key}']"], arr)


def test_restarted_writer_continues_a_healthy_chain(tmp_path):
    snaps = _publish_chain(tmp_path, n=4)       # base 0, d1, d2, base 3
    store = SnapshotStore(str(tmp_path), base_every=BASE_EVERY,
                          chunk_bytes=1 << 12)
    mutated = {k: a.copy() for k, a in snaps[-1].items()}
    mutated["k"][:100] = 0.0
    rec = store.publish("kv", 4, mutated)
    assert rec.kind == "delta" and rec.chain_pos == 1
    step, leaves = store.restore("kv")
    assert step == 4
    np.testing.assert_array_equal(leaves["['k']"], mutated["k"])
