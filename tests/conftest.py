"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs to launch/dryrun.py ONLY)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_params(arch: str, seed: int = 0):
    from repro.configs import base
    from repro.models import params as P, transformer

    cfg = base.get(arch, smoke=True)
    prm = P.materialize(jax.random.PRNGKey(seed), transformer.param_spec(cfg))
    return cfg, prm
