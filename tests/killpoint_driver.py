"""Mesh-level kill-point driver: drop a host mid-run, resume elastically.

Run as a script (the pytest wrapper in ``test_fault.py`` and the CI smoke
step both do); it re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so a CPU-only
machine presents a multi-device platform (the test suite's conftest
requires the *in-process* device count to stay 1, hence the subprocess).

Scenario (all deterministic — injected session clock, seeded synthetic
batches):

  golden    train ``--steps`` on a (2, 2) ('data', 'model') mesh over
            4 devices (2 simulated hosts x 2 devices), no failures.
  failure   same run with the ``fault`` preset heartbeating per step and
            v2 shard checkpoints every ``--ckpt-every``; host 1 stops
            beating at ``--fail-at``, the controller declares it failed
            after the grace window, and the run halts.
  recover   ``Session.restore(elastic=True)`` over host 0's surviving
            2 devices: ``plan_elastic_remesh`` picks the (1, 2) grid
            (smallest merge factor at equal device count), the v2
            checkpoint re-places under the shrunken mesh, and training
            resumes to ``--steps``.

The driver prints one JSON object on the last stdout line: golden/resumed
losses, the detection step, the remesh plan, and the restored step. The
wrapper asserts the resumed tail matches golden within lossy checkpoint
bounds.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD_ENV = "REPRO_KILLPOINT_CHILD"
_DEVICES = 8


def reexec_with_devices() -> int:
    """Re-run this script in a child with the multi-device XLA platform."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES} "
        + env.get("XLA_FLAGS", "")).strip()
    env[_CHILD_ENV] = "1"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, os.path.abspath(__file__)]
                          + sys.argv[1:], env=env)
    return proc.returncode


def run_scenario(steps: int, fail_at: int, ckpt_every: int,
                 ckpt_dir: str, grace_s: float = 2.5) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import base as configs
    from repro.core import Session
    from repro.distributed import sharding
    from repro.launch import train

    assert len(jax.devices()) >= 4, jax.devices()
    cfg = configs.get("smollm-135m", smoke=True)
    shape = configs.SMOKE_SHAPE
    step_cfg = train.StepConfig()

    def synth_batch(step: int) -> dict:
        rng = np.random.RandomState(1000 + step)
        b, s = shape.global_batch, shape.seq_len
        return {
            "tokens": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
            "labels": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (b, s)), jnp.int32),
        }

    def fresh_state(mesh):
        with sharding.mesh_context(mesh):
            return train.init_state(cfg, jax.random.PRNGKey(0), step_cfg.opt)

    # -- golden: the non-failed run on the full (2, 2) mesh ------------------
    mesh_full = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    jit_full, _, _, _ = train.jit_train_step(cfg, mesh_full, step_cfg, shape,
                                             donate=False)
    golden_losses = []
    with sharding.mesh_context(mesh_full):
        state = fresh_state(mesh_full)
        for i in range(steps):
            state, metrics = jit_full(state, synth_batch(i))
            golden_losses.append(float(metrics["loss"]))

    # -- failure: fault preset + checkpoints; host 1 dies at fail_at ---------
    # hosts 0/1 own devices 0-1/2-3; the injected clock advances 1s per step
    now = [0.0]
    plan = {
        "streams": ["train_state", "health"],
        "workers": 2,
        "tasks": {
            "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                           "every": ckpt_every, "placement": "async",
                           "options": {"directory": ckpt_dir}},
            "fault": {"stream": "health", "preset": "fault", "every": 1,
                      "placement": "sync", "pipelined": False,
                      "options": {"hosts": [0, 1], "grace_s": grace_s}},
        },
    }
    detect_step = None
    failed = []
    with sharding.mesh_context(mesh_full):
        state = fresh_state(mesh_full)
        with Session(plan, clock=lambda: now[0],
                     raise_on_error=True) as session:
            session.set_checkpoint_meta(mesh=mesh_full)
            ctrl = session.fault_controller()
            for i in range(steps):
                now[0] += 1.0
                state, metrics = jit_full(state, synth_batch(i))
                session.emit("train_state", i, lambda s=state: s)
                beats = {0: 0.1}
                if i < fail_at:
                    beats[1] = 0.1          # host 1 beats until it dies
                session.emit("health", i, {"hosts": beats})
                failed = ctrl.failed_hosts()
                if failed:
                    detect_step = i         # halt: the mesh lost a host
                    break
            session.wait_idle()
        fail_report = session.report()

    assert failed == [1], f"expected host 1 failed, got {failed}"
    assert detect_step is not None and detect_step >= fail_at

    # -- recover: elastic restore on host 0's surviving devices --------------
    survivors = list(jax.devices()[:2])
    resume_plan = {"streams": ["train_state"], "workers": 2, "tasks": {
        "checkpoint": {"stream": "train_state", "preset": "checkpoint",
                       "every": ckpt_every, "placement": "async",
                       "options": {"directory": ckpt_dir}}}}
    resumed_losses: dict[int, float] = {}
    with Session(resume_plan, raise_on_error=True) as session:
        template = train.state_spec(cfg)
        start, state = session.restore(
            template, elastic=True, devices=survivors,
            make_shardings=lambda m: train.state_shardings(cfg, m))
        rm = session.remesh
        mesh_new = rm.mesh
        with sharding.mesh_context(mesh_new):
            jit_new, _, _, _ = train.jit_train_step(cfg, mesh_new, step_cfg,
                                                    shape, donate=False)
            session.set_checkpoint_meta(mesh=mesh_new)
            for i in range(start + 1, steps):
                state, metrics = jit_new(state, synth_batch(i))
                resumed_losses[i] = float(metrics["loss"])
                session.emit("train_state", i, lambda s=state: s)
            session.wait_idle()

    return {
        "golden_losses": golden_losses,
        "resumed_losses": resumed_losses,
        "detect_step": detect_step,
        "restored_step": start,
        "failed_hosts": failed,
        "new_shape": list(rm.plan.new_shape),
        "merge_factor": rm.plan.model_merge_factor,
        "fault_report": {
            "failed_hosts": fail_report["fault"]["failed_hosts"],
            "alive_hosts": fail_report["fault"]["alive_hosts"],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=9)
    ap.add_argument("--fail-at", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if os.environ.get(_CHILD_ENV) != "1":
        sys.exit(reexec_with_devices())

    import tempfile
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_killpoint_")
    out = run_scenario(args.steps, args.fail_at, args.ckpt_every, ckpt_dir)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
