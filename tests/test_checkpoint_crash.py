"""Crash consistency: kill-point tests over the commit/durability protocol.

The commit protocol is blobs (fsynced) -> manifest (fsynced, renamed in) ->
directory publish (sibling rename aside, rename in, parent fsync, remove
aside). These tests simulate a crash at each stage — by reconstructing the
exact on-disk debris that stage leaves behind — and assert that ``restore``
either returns the previous step or raises cleanly, for BOTH layouts:
v2 (packed shards, offset table) and v1 (one blob file per leaf).
"""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import (CheckpointConfig, CheckpointCorruptError,
                              CheckpointManager, serialization as ser)
from repro.core.insitu import InSituMode

FORMATS = (1, 2)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(k, (64, 32), jnp.float32),
              "b": jnp.zeros((32,), jnp.float32)}
    st = optim.init(params, optim.AdamWConfig())
    return {"params": params, "opt": {"mu": st.mu, "nu": st.nu}}


def _mgr(directory, fmt, **kw):
    return CheckpointManager(CheckpointConfig(
        str(directory), mode=InSituMode.SYNC, every=1, format=fmt, **kw))


def _data_files(d):
    return sorted(f for f in os.listdir(d) if f.endswith(".bin"))


def _backdate(path, age_s=3600):
    """Make debris look old: sweep_stale keeps fresh tmp dirs (they may
    belong to a still-live writer) and only removes genuinely stale ones.
    Liveness looks at the dir AND its contents, so backdate both."""
    t = time.time() - age_s
    for p in [path] + [os.path.join(path, n) for n in os.listdir(path)]:
        os.utime(p, (t, t))


# -- kill point 1: blobs written, no manifest ---------------------------------

@pytest.mark.parametrize("fmt", FORMATS)
def test_crash_before_manifest_previous_step_restores(tmp_path, fmt):
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(1, state)
    # crash mid-save of step 2: tmp dir holds blobs but no manifest yet
    tmp = tmp_path / ".tmp_step_000000002"
    shutil.copytree(tmp_path / "step_000000001", tmp)
    os.remove(tmp / "manifest.json")
    _backdate(tmp)
    assert mgr.list_steps() == [1]
    step, _ = mgr.restore(state)
    assert step == 1
    # a restarted manager sweeps the (stale) debris
    m2 = _mgr(tmp_path, fmt)
    assert not (tmp_path / ".tmp_step_000000002").exists()
    assert m2.list_steps() == [1]


# -- kill point 2: manifest tmp written, rename never happened ----------------

@pytest.mark.parametrize("fmt", FORMATS)
def test_crash_before_manifest_rename_is_invisible(tmp_path, fmt):
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(1, state)
    tmp = tmp_path / ".tmp_step_000000003"
    shutil.copytree(tmp_path / "step_000000001", tmp)
    os.replace(tmp / "manifest.json", tmp / "manifest.json.tmp")
    _backdate(tmp)
    assert mgr.list_steps() == [1]
    step, _ = mgr.restore(state)
    assert step == 1
    _mgr(tmp_path, fmt)
    assert not tmp.exists()


def test_sweep_spares_fresh_tmp_dirs_of_a_live_writer(tmp_path):
    """A replacement manager must not rmtree a tmp dir another writer is
    actively filling: fresh tmp dirs survive the sweep, stale ones don't."""
    state = _state()
    mgr = _mgr(tmp_path, 2)
    mgr.save(1, state)
    fresh = tmp_path / ".tmp_step_000000002"
    shutil.copytree(tmp_path / "step_000000001", fresh)
    _mgr(tmp_path, 2)
    assert fresh.exists()                  # could be in-flight: kept
    _backdate(fresh)
    _mgr(tmp_path, 2)
    assert not fresh.exists()              # genuinely stale: swept


# -- kill point 3: crash inside commit, old copy moved aside ------------------

@pytest.mark.parametrize("fmt", FORMATS)
def test_crash_mid_commit_recovers_displaced_step(tmp_path, fmt):
    """Crash between the aside rename and the publish rename: the only copy
    of the step sits at .old_step_N. A restarted manager re-publishes it —
    the pre-fix rmtree-then-replace protocol would have destroyed it."""
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(5, state)
    os.replace(tmp_path / "step_000000005",
               tmp_path / ".old_step_000000005")
    assert mgr.list_steps() == []          # mid-commit: step invisible...
    m2 = _mgr(tmp_path, fmt)
    assert m2.list_steps() == [5]          # ...until recovery republishes it
    step, restored = m2.restore(state)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


@pytest.mark.parametrize("fmt", FORMATS)
def test_crash_after_commit_drops_stale_aside_copy(tmp_path, fmt):
    """Crash after the publish rename but before the aside copy's removal:
    both step_N and .old_step_N exist; recovery keeps the new one."""
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(5, state)
    shutil.copytree(tmp_path / "step_000000005",
                    tmp_path / ".old_step_000000005")
    m2 = _mgr(tmp_path, fmt)
    assert not (tmp_path / ".old_step_000000005").exists()
    assert m2.list_steps() == [5]


def test_resave_same_step_never_deletes_the_only_copy(tmp_path):
    """Overwriting a step goes through the aside rename, so at every instant
    one complete copy exists; the result is the newer save."""
    state = _state()
    mgr = _mgr(tmp_path, 2)
    mgr.save(7, state)
    mgr.save(7, _state(seed=1))            # same step again
    assert not (tmp_path / ".old_step_000000007").exists()
    step, restored = mgr.restore(_state(seed=1))
    assert step == 7
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_state(seed=1)["params"]["w"]))


# -- corruption: truncated / missing stored bytes -----------------------------

@pytest.mark.parametrize("fmt", FORMATS)
def test_truncated_data_file_raises_corrupt_error(tmp_path, fmt):
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(1, state)
    d = tmp_path / "step_000000001"
    victim = d / _data_files(d)[-1]
    victim.write_bytes(victim.read_bytes()[:-16])
    with pytest.raises(CheckpointCorruptError, match="truncated"):
        mgr.restore(state)


def test_missing_v1_blob_file_raises_corrupt_error(tmp_path):
    state = _state()
    mgr = _mgr(tmp_path, 1)
    mgr.save(1, state)
    d = tmp_path / "step_000000001"
    os.remove(d / _data_files(d)[0])
    with pytest.raises(CheckpointCorruptError, match="missing blob"):
        mgr.restore(state)


def test_missing_v2_shard_file_raises_corrupt_error(tmp_path):
    state = _state()
    mgr = _mgr(tmp_path, 2)
    mgr.save(1, state)
    os.remove(tmp_path / "step_000000001" / "shard_000.bin")
    with pytest.raises(CheckpointCorruptError, match="missing shard"):
        mgr.restore(state)


@pytest.mark.parametrize("fmt", FORMATS)
def test_template_leaf_missing_from_manifest_raises_keyerror(tmp_path, fmt):
    """Tree-shape drift: restoring into a template with an extra leaf names
    the leaf instead of failing deep inside decode."""
    state = _state()
    mgr = _mgr(tmp_path, fmt)
    mgr.save(1, state)
    grown = dict(state)
    grown["extra"] = jnp.zeros((4,), jnp.float32)
    with pytest.raises(KeyError, match="extra.*tree shape drifted"):
        mgr.restore(grown)


# -- the protocol end to end under async scheduling ---------------------------

def test_async_saves_survive_manager_restart_with_debris(tmp_path):
    state = _state()
    m1 = _mgr(tmp_path, 2)
    m1.save(1, state)
    m1.save(2, state)
    m1.runtime.drain()
    # dead job left a partial tmp AND a stranded aside copy of step 1
    shutil.copytree(tmp_path / "step_000000002",
                    tmp_path / ".tmp_step_000000003")
    _backdate(tmp_path / ".tmp_step_000000003")
    os.replace(tmp_path / "step_000000001",
               tmp_path / ".old_step_000000001")
    m2 = CheckpointManager(CheckpointConfig(str(tmp_path),
                                            mode=InSituMode.ASYNC, every=1))
    assert m2.list_steps() == [1, 2]
    step, _ = m2.restore(state)
    assert step == 2
    m2.finish()
