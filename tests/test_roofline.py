"""Roofline analyzer: HLO collective parsing + term math."""
import pytest

from repro.configs import base
from repro.roofline import analysis as R

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[128,1024]{1,0} parameter(0)
  %ag = bf16[2048,1024]{1,0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[256]{0} all-reduce-start(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ard = f32[256]{0} all-reduce-done(%ar)
  %rs = f32[64,8]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = s8[1024]{0} collective-permute-start(%z), source_target_pairs={{0,1},{1,0}}
  %cpd = s8[1024]{0} collective-permute-done(%cp)
  %a2a = bf16[32,32]{1,0} all-to-all(%w), replica_groups={{0,1}}, dimensions={0}
}
"""


def test_parse_collectives_kinds_and_counts():
    ops = R.parse_collectives(HLO, default_group=256)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_group_size_parsing():
    ops = {o.kind: o for o in R.parse_collectives(HLO, default_group=99)}
    assert ops["all-gather"].group_size == 16      # brace list
    assert ops["all-reduce"].group_size == 16      # iota [16,16]
    assert ops["reduce-scatter"].group_size == 4
    assert ops["all-to-all"].group_size == 2


def test_wire_byte_math():
    ops = {o.kind: o for o in R.parse_collectives(HLO)}
    # all-gather: result 2048*1024*2B * (15/16)
    assert ops["all-gather"].wire_bytes == pytest.approx(
        2048 * 1024 * 2 * 15 / 16)
    # all-reduce: 2 * size * (g-1)/g
    assert ops["all-reduce"].wire_bytes == pytest.approx(
        2 * 256 * 4 * 15 / 16)
    # reduce-scatter: result * g * (g-1)/g
    assert ops["reduce-scatter"].wire_bytes == pytest.approx(
        64 * 8 * 4 * 4 * 3 / 4)
    # collective-permute: one hop, s8 => 1 byte/elem
    assert ops["collective-permute"].wire_bytes == pytest.approx(1024)


def test_async_pairs_counted_once():
    ops = R.parse_collectives(HLO)
    assert sum(1 for o in ops if o.kind == "all-reduce") == 1
    assert sum(1 for o in ops if o.kind == "collective-permute") == 1


def test_report_bottleneck_and_fraction():
    rep = R.analyze(
        arch="x", shape="train_4k", mesh_desc="16x16", chips=256,
        cost={"flops": 1e12, "bytes accessed": 1e9}, hlo_text=HLO,
        model_flops_global=0.7 * 1e12 * 256)
    assert rep.compute_s == pytest.approx(1e12 / R.PEAK_FLOPS_BF16)
    assert rep.bottleneck == "compute"
    assert 0.6 < rep.roofline_fraction() <= 0.71
    assert rep.useful_flops_ratio == pytest.approx(0.7)


def test_model_flops_moe_counts_active_only():
    dense = base.get("granite-3-2b")
    moe = base.get("moonshot-v1-16b-a3b")
    shape = base.SHAPES["train_4k"]
    f_moe = R.model_flops(moe, shape)
    # MoE: active params far fewer than total
    assert moe.n_active_params() < 0.5 * moe.n_params()
    assert f_moe < 6.0 * moe.n_params() * shape.global_batch * shape.seq_len


def test_decode_flops_use_one_token():
    cfg = base.get("granite-3-2b")
    tr = R.model_flops(cfg, base.SHAPES["train_4k"])
    dec = R.model_flops(cfg, base.SHAPES["decode_32k"])
    assert dec < tr / 100


def test_unknown_dtype_collectives_are_counted_not_dropped():
    """f8e8m0-style lines must surface in the report instead of silently
    undercounting wire bytes."""
    hlo = ("%ar = f8e8m0[4096]{0} all-reduce(%x), "
           "replica_groups={{0,1,2,3}}, to_apply=%add")
    ops = R.parse_collectives(hlo)
    assert len(ops) == 1
    assert ops[0].dtype == "f8e8m0"
    assert ops[0].elem_bytes == 0 and ops[0].wire_bytes == 0.0
    assert ops[0].shape == (4096,) and ops[0].group_size == 4
    rpt = R.analyze(arch="a", shape="s", mesh_desc="m", chips=4,
                    cost={"flops": 1e12, "bytes accessed": 1e9},
                    hlo_text=hlo, model_flops_global=1e12)
    assert "f8e8m0" in rpt.note and "lower bound" in rpt.note
    assert rpt.collectives_by_kind["all-reduce"]["count"] == 1
    assert rpt.collectives_by_kind["all-reduce"]["unknown_dtype"] == 1
    assert rpt.wire_bytes_per_device == 0.0


def test_known_dtype_report_has_no_unknown_note():
    rpt = R.analyze(arch="a", shape="s", mesh_desc="m", chips=4,
                    cost={"flops": 1e12, "bytes accessed": 1e9},
                    hlo_text=HLO, model_flops_global=1e12)
    assert rpt.note == ""
    assert all("unknown_dtype" not in e
               for e in rpt.collectives_by_kind.values())


def test_kernel_report_places_fn_on_roofline():
    import jax.numpy as jnp

    from repro.roofline.kernels import RIDGE_INTENSITY, kernel_report

    def mm(a, b):
        return a @ b

    a = jnp.ones((256, 256), jnp.float32)
    rpt = kernel_report(mm, (a, a), name="mm", measure=True)
    assert rpt.name == "mm"
    assert rpt.bound in ("compute", "memory")
    assert rpt.roofline_s == max(rpt.compute_s, rpt.memory_s)
    assert rpt.ridge_intensity == RIDGE_INTENSITY
    assert rpt.measured_s is not None and rpt.measured_s > 0
    assert rpt.achieved_fraction is not None
    d = rpt.to_dict()
    assert d["bound"] == rpt.bound and "flops" in d
    # overrides drive the placement when cost_analysis is not trusted
    # (interpret-mode pallas prices the interpreter, not the kernel)
    rpt2 = kernel_report(mm, (a, a), flops_override=1e9, bytes_override=1e6)
    assert rpt2.flops == 1e9 and rpt2.bytes_accessed == 1e6
    assert rpt2.intensity == pytest.approx(1e3)
    assert rpt2.bound == "compute"   # 5.1us of math vs 1.2us of HBM
    assert rpt2.measured_s is None
